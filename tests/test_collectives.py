"""Runtime collectives (DESIGN.md §16).

Covers the tree_reduce serial-chain bugfix (balanced sub-trees at every
arity, live schedule isomorphic to the simulator spec), the collective
k-ary reduction being bitwise-equal to the client-side fold on every
backend, broadcast moving bytes over the scheduler link exactly once on
a live 3-agent cluster (the rest agent→agent), the broadcast-residue
regression (an N-agent keyed fan-out costs ONE scheduler-link copy),
shuffle round-tripping skewed fragments, placement hints, and SIGKILL
recovery mid-broadcast / mid-tree_reduce.
"""
import math
import os
import signal
import time

import numpy as np
import pytest

from repro.algorithms.common import tree_reduce as client_tree_reduce
from repro.algorithms.common import tree_reduce_spec
from repro.core import api, collectives
from repro.core.collectives import reduce_spec, spec_depth

BIG = 4096       # float64 elements = 32 KiB, above RJAX_INLINE_MAX
SMALL = 64       # 512 B, below it


def _cluster(n_agents=2, wpn=1, **kw):
    return api.runtime_start(backend="cluster", n_agents=n_agents,
                             workers_per_node=wpn, **kw)


def gen_arr(seed, n=BIG):
    return np.random.default_rng(seed).standard_normal(n)


def gen_small(n):
    return np.ones(n, dtype=np.float64)


def add(a, b):
    return a + b


def consume(a):
    return float(np.asarray(a).sum())


# ------------------------------------------------------- shapes / validation
def test_arity_validation():
    for bad in (1, 0, -3):
        with pytest.raises(ValueError):
            tree_reduce_spec(8, arity=bad)
        with pytest.raises(ValueError):
            client_tree_reduce([1, 2, 3], add, arity=bad)
        with pytest.raises(ValueError):
            reduce_spec(8, arity=bad)
        with pytest.raises(ValueError):
            collectives.tree_reduce([1, 2, 3], add, arity=bad)
    with pytest.raises(ValueError):
        client_tree_reduce([], add)
    with pytest.raises(ValueError):
        collectives.tree_reduce([], add)


def test_spec_is_balanced_not_a_chain():
    """The old fold reduced each arity group serially: at arity 4 the
    critical path was ~n-1 merges.  Fixed: the pairwise spec stays n-1
    merges total but log-depth at EVERY arity, and the k-ary collective
    spec has exactly ceil(log_arity n) levels."""
    for n in range(2, 40):
        for arity in (2, 3, 4, 8):
            spec = tree_reduce_spec(n, arity=arity)
            assert len(spec) == n - 1        # pairwise merge count invariant
            d = spec_depth(spec, n)
            assert d >= math.ceil(math.log2(n))
            # log-depth: far below the serial chain for any wide tree
            assert d <= math.ceil(math.log2(n)) + math.ceil(
                math.log(n) / math.log(arity))
            kspec = reduce_spec(n, arity=arity)
            assert spec_depth(kspec, n) == math.ceil(
                math.log(n) / math.log(arity))
    assert spec_depth(tree_reduce_spec(16, arity=2), 16) == 4
    assert spec_depth(tree_reduce_spec(16, arity=4), 16) == 4  # was 15


def test_reduce_spec_consumes_each_id_exactly_once():
    for n in range(1, 18):
        for arity in (2, 3, 4):
            spec = reduce_spec(n, arity=arity)
            used = [c for _, children in spec for c in children]
            assert len(used) == len(set(used))
            ids = set(range(n)) | {n + mi for mi, _ in spec}
            assert set(used) <= ids
            if n > 1:
                # every id except the root is consumed exactly once
                assert len(used) == len(ids) - 1
                assert n + spec[-1][0] not in used
            for _, children in spec:
                assert 2 <= len(children) <= arity


def test_live_reduction_isomorphic_to_spec():
    """Satellite check: the client-side tree_reduce must execute exactly
    the schedule tree_reduce_spec predicts — same merges, same order —
    for n in 1..17 x arity in {2, 3, 4}."""
    for n in range(1, 18):
        for arity in (2, 3, 4):
            log = []

            def rec(a, b):
                log.append((a, b))
                return len(log) + n - 1     # id of the merge node

            out = client_tree_reduce(list(range(n)), rec, arity=arity)
            assert log == [pair for _, pair in tree_reduce_spec(n, arity)]
            assert out == (n - 1 + len(log) if n > 1 else 0)


def test_collective_matches_client_fold_bitwise_thread():
    """The k-ary collective performs the same pairwise merges in the same
    order as the fixed client-side fold: float64 results are bitwise
    identical, not merely close."""
    api.runtime_start(backend="thread", n_workers=4)
    try:
        merge_t = api.task(add, name="merge")
        for n in (1, 2, 5, 8, 13, 16):
            leaves = [gen_arr(i, 257) for i in range(n)]
            for arity in (2, 3, 4, 8):
                expect = client_tree_reduce(leaves, add, arity=arity)
                got = api.wait_on(collectives.tree_reduce(
                    list(leaves), merge_t, arity=arity))
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(expect))
    finally:
        api.runtime_stop(wait=False)


@pytest.mark.parametrize("backend,kw", [
    ("process", {"n_workers": 2}),
    ("cluster", {"n_agents": 2, "workers_per_node": 1}),
])
def test_collective_matches_client_fold_bitwise_remote(backend, kw):
    leaves = [gen_arr(i, 512) for i in range(9)]
    expect = {a: client_tree_reduce(leaves, add, arity=a) for a in (2, 3)}
    api.runtime_start(backend=backend, **kw)
    try:
        merge_t = api.task(add, name="merge")
        for arity, exp in expect.items():
            got = api.wait_on(collectives.tree_reduce(
                list(leaves), merge_t, arity=arity))
            np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    finally:
        api.runtime_stop(wait=False)


def test_collective_accepts_future_leaves():
    api.runtime_start(backend="thread", n_workers=4)
    try:
        gen_t = api.task(gen_arr, name="gen")
        merge_t = api.task(add, name="merge")
        leaves = api.map_tasks(gen_t, [(i, 128) for i in range(7)])
        got = api.wait_on(collectives.tree_reduce(leaves, merge_t, arity=3))
        expect = client_tree_reduce([gen_arr(i, 128) for i in range(7)],
                                    add, arity=3)
        np.testing.assert_array_equal(got, expect)
    finally:
        api.runtime_stop(wait=False)


# ------------------------------------------------------------ placement hint
def test_scheduler_placement_hint_biases_locality_take():
    from repro.core.dag import TaskGraph, TaskNode
    from repro.core.futures import ObjectStore
    from repro.core.scheduler import Scheduler

    g = TaskGraph()
    store = ObjectStore()
    s = Scheduler(g, store, policy="locality", workers_per_node=1)

    def node():
        return TaskNode(task_id=g.next_task_id(), name="t", fn=None,
                        args=(), kwargs={}, dep_keys=set(), out_keys=[])

    a, b = node(), node()
    g.add_task(a)
    g.add_task(b)
    s.set_hint(b.task_id, 1)
    s.push(a.task_id)
    s.push(b.task_id)
    # worker on node 1 prefers the hinted task over FIFO order
    assert s.take(1, timeout=1) == b.task_id
    assert s.take(1, timeout=1) == a.task_id
    # hints are consumed at take
    assert not s._hints


# -------------------------------------------------------------- broadcast
def test_broadcast_thread_backend_plain_store():
    api.runtime_start(backend="thread", n_workers=2)
    try:
        v = np.arange(SMALL, dtype=np.float64)
        fut = api.broadcast(v)
        np.testing.assert_array_equal(api.wait_on(fut), v)
        outs = [api.task(consume, name="consume")(fut) for _ in range(4)]
        assert api.wait_on(outs) == [float(v.sum())] * 4
    finally:
        api.runtime_stop(wait=False)


def test_broadcast_single_scheduler_copy_three_agents():
    """Acceptance: broadcast moves the value over the scheduler's own
    link AT MOST once; every other agent receives it agent→agent —
    verified by the transfer ledger on a live 3-agent cluster."""
    rt = _cluster(n_agents=3, wpn=1)
    try:
        v = np.arange(BIG, dtype=np.float64)
        shipped0 = rt.executor.bytes_shipped
        fetch0 = rt.executor.fetch_bytes
        p2p0 = rt.store.transfer_detail()["p2p_bytes"]
        fut = api.broadcast(v)
        shipped = rt.executor.bytes_shipped - shipped0
        # ONE encoded copy crossed the scheduler link ...
        assert shipped >= v.nbytes
        assert shipped < 2 * v.nbytes
        # ... and the other two agents pulled peer-to-peer
        assert rt.executor.fetch_bytes - fetch0 >= 2 * v.nbytes
        assert rt.store.transfer_detail()["p2p_bytes"] - p2p0 == 2 * v.nbytes
        assert rt.executor.broadcasts == 1
        # every agent now holds the key: consumers anywhere cost refs only
        puts0 = rt.executor.puts
        outs = [api.task(consume, name="consume")(fut) for _ in range(9)]
        assert api.wait_on(outs, timeout=90) == [float(v.sum())] * 9
        assert rt.executor.puts == puts0
        np.testing.assert_array_equal(api.wait_on(fut), v)
    finally:
        api.runtime_stop(wait=False)


def test_broadcast_residue_regression_one_put_then_peer_fetches():
    """Satellite 3: a keyed scheduler-resident datum fanned out to N
    agents used to cost one Put PER AGENT, serially, on the scheduler
    thread.  Now the first consumer agent gets the only Put and every
    other agent pulls the key from that agent's plane."""
    rt = _cluster(n_agents=3, wpn=1)
    try:
        part = api.task(gen_small, name="gen_small")(SMALL)
        # inline result: the value lives in the scheduler store only
        api.wait_on(part)
        puts0, fetches0 = rt.executor.puts, rt.executor.fetches
        # pin the key onto agent via one consumer, then fan out
        api.wait_on(api.task(consume, name="consume")(part))
        assert rt.executor.puts - puts0 == 1
        outs = [api.task(consume, name="consume")(part) for _ in range(8)]
        assert api.wait_on(outs, timeout=90) == [float(SMALL)] * 8
        # the fan-out cost ZERO further scheduler-link copies: the other
        # two agents each pulled the key agent→agent exactly once
        assert rt.executor.puts - puts0 == 1
        fetched = rt.executor.fetches - fetches0
        assert 1 <= fetched <= 2
        assert rt.store.transfer_detail()["p2p_bytes"] > 0
    finally:
        api.runtime_stop(wait=False)


def test_broadcast_survives_sigkill_mid_fanout():
    """SIGKILL one agent while the broadcast frontier is running: the
    wave settles on the surviving agents, consumers everywhere converge
    (the respawned agent picks the key up as a plain Put)."""
    rt = _cluster(n_agents=3, wpn=1, max_retries=4)
    try:
        v = np.arange(BIG, dtype=np.float64)
        restarts0 = rt.executor.agent_restarts
        os.kill(rt.cluster._procs[2].pid, signal.SIGKILL)
        fut = api.broadcast(v)    # frontier runs against a dying agent
        outs = [api.task(consume, name="consume", max_retries=4)(fut)
                for _ in range(9)]
        assert api.wait_on(outs, timeout=120) == [float(v.sum())] * 9
        deadline = time.time() + 30
        while time.time() < deadline \
                and rt.executor.agent_restarts == restarts0:
            time.sleep(0.05)
        assert rt.executor.agent_restarts >= 1
    finally:
        api.runtime_stop(wait=False)


def test_tree_reduce_survives_sigkill_of_leaf_home():
    """SIGKILL the agent holding a leaf mid-reduction: lineage recovery
    re-executes the lost producers and the collective converges to the
    same value the thread backend computes."""
    from repro.core.futures import RemoteValue

    leaves_n = 6
    api.runtime_start(backend="thread", n_workers=2)
    try:
        expect = client_tree_reduce(
            [gen_arr(i) for i in range(leaves_n)], add, arity=3)
    finally:
        api.runtime_stop(wait=False)

    rt = _cluster(n_agents=2, wpn=1, max_retries=4)
    try:
        gen_t = api.task(gen_arr, name="gen", max_retries=4)
        merge_t = api.task(add, name="merge", max_retries=4)
        leaves = api.map_tasks(gen_t, [(i,) for i in range(leaves_n)])
        api.barrier()
        rv = rt.store.get_nowait(leaves[0].key, materialize=False)
        assert isinstance(rv, RemoteValue)
        os.kill(rt.cluster._procs[rv.node].pid, signal.SIGKILL)
        out = collectives.tree_reduce(leaves, merge_t, arity=3)
        got = api.wait_on(out, timeout=120)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
        assert rt.executor.agent_restarts >= 1
    finally:
        api.runtime_stop(wait=False)


# ---------------------------------------------------------------- shuffle
def _mod_partition(frag, n_out):
    frag = np.asarray(frag)
    return [frag[frag % n_out == p] for p in range(n_out)]


def test_shuffle_round_trips_skewed_fragments():
    """All-to-all over wildly skewed fragment sizes: every input element
    lands in exactly one output partition, partitions agree with the
    partition function, nothing is lost or duplicated."""
    api.runtime_start(backend="thread", n_workers=4)
    try:
        rng = np.random.default_rng(7)
        sizes = [1, 900, 3, 250, 40]            # heavy skew
        frags = [rng.integers(0, 10_000, size=s).astype(np.int64)
                 for s in sizes]
        n_out = 3
        outs = api.wait_on(collectives.shuffle(frags, _mod_partition, n_out))
        assert len(outs) == n_out
        for p, part in enumerate(outs):
            assert np.all(np.asarray(part) % n_out == p)
        got = np.sort(np.concatenate([np.asarray(o) for o in outs]))
        assert np.array_equal(got, np.sort(np.concatenate(frags)))
    finally:
        api.runtime_stop(wait=False)


def test_shuffle_with_combine_task_on_cluster():
    _cluster(n_agents=2, wpn=1)
    try:
        frags = [np.arange(i * 100, i * 100 + 90, dtype=np.int64)
                 for i in range(4)]
        sum_t = api.task(add, name="psum")
        outs = api.wait_on(collectives.shuffle(
            frags, _mod_partition, 2, combine=sum_t))
        whole = np.concatenate(frags)
        for p in range(2):
            assert np.asarray(outs[p]).item() if False else True
            assert int(np.asarray(outs[p]).sum()) == \
                int(whole[whole % 2 == p].sum())
    finally:
        api.runtime_stop(wait=False)


def test_collective_fns_ship_by_value_to_agents():
    # merge/partition callables travel inside task ARGS, not through the
    # fn registry — a closure (or a script's __main__ function) does not
    # pickle by reference, and before the _Fn wrapper it crashed the
    # receiving agent's reader loop mid-unpickle
    _cluster(n_agents=2, wpn=1)
    try:
        scale = 2.0

        def scaled_add(a, b):       # closure: by-reference pickle fails
            return (a + b) * scale

        merge_t = api.task(scaled_add, name="cmerge")
        parts = [np.full(64, float(i)) for i in range(5)]
        got = api.wait_on(collectives.tree_reduce(parts, merge_t, arity=3))
        want = collectives.tree_reduce(parts, scaled_add, arity=3)
        np.testing.assert_array_equal(got, want)

        def by_parity(a, n):
            return [a[a % n == i] for i in range(n)]

        frags = [np.arange(i * 10, i * 10 + 7, dtype=np.int64)
                 for i in range(3)]
        outs = api.wait_on(collectives.shuffle(frags, by_parity, 2))
        whole = np.concatenate(frags)
        back = np.sort(np.concatenate([np.asarray(o) for o in outs]))
        np.testing.assert_array_equal(back, np.sort(whole))
    finally:
        api.runtime_stop(wait=False)


def test_shuffle_validation():
    api.runtime_start(backend="thread", n_workers=2)
    try:
        with pytest.raises(ValueError):
            collectives.shuffle([], _mod_partition, 2)
        with pytest.raises(ValueError):
            collectives.shuffle([np.arange(4)], _mod_partition, 0)
    finally:
        api.runtime_stop(wait=False)


# -------------------------------------------- algorithms ride the collective
def test_linreg_task_count_uses_kary_tree():
    from repro.algorithms import linreg

    api.runtime_start(backend="thread", n_workers=4)
    try:
        res = linreg.run_linreg(n_rows=2000, p=10, n_pred=400, fragments=16,
                                pred_blocks=2, merge_arity=8)
        # 16 leaves at arity 8: 2 group merges + 1 root per tree, not 15
        assert res.n_tasks == 16 * 3 + 2 * 3 + 1 + 2 * 2
    finally:
        api.runtime_stop(wait=False)
