"""Real multi-node execution over TCP: LocalCluster end-to-end
(DESIGN.md §12).  These are the CI cluster-smoke tests: the quickstart
DAG and a KNN tile pipeline run against two real node agents on
localhost; the heavy variants are ``slow``-marked."""
import os
import signal
import time

import numpy as np
import pytest

from repro.core import api
from repro.core.executors import WorkerCrashedError
from repro.core.futures import TaskFailedError

BIG = 4096   # float64 elements — comfortably above the wire frame floor


@pytest.fixture(scope="module")
def crt():
    r = api.runtime_start(backend="cluster", n_agents=2, workers_per_node=2)
    yield r
    api.runtime_stop(wait=False)


def test_cluster_geometry(crt):
    assert crt.n_workers == 4
    assert crt.workers_per_node == 2
    s = crt.stats()["executor"]
    assert s["backend"] == "cluster"
    assert s["n_agents"] == 2 and s["workers_per_node"] == 2


def test_quickstart_dag(crt):
    """The paper's Fig. 2 program against real TCP agents."""
    add = api.task(lambda x, y: x + y, name="add")
    res1 = add(4, 5)
    res2 = add(6, 7)
    res3 = add(res1, res2)
    assert api.wait_on(res3) == 22


def test_big_arrays_cross_the_wire(crt):
    gen = api.task(lambda n: np.arange(n, dtype=np.float64), name="gen")
    out = api.wait_on(gen(BIG))
    np.testing.assert_array_equal(out, np.arange(BIG, dtype=np.float64))


def test_send_once_reuse_many(crt):
    """The acceptance property: a keyed ndarray input is shipped to a
    given node at most once, no matter how many tasks there read it."""
    ex = crt.executor
    gen = api.task(lambda n: np.ones(n), name="gen")
    tot = api.task(lambda a: float(np.sum(a)), name="tot")
    part = gen(BIG)
    api.wait_on(part)
    puts0, refs0 = ex.puts, ex.refs
    outs = [tot(part) for _ in range(10)]
    assert api.wait_on(outs) == [float(BIG)] * 10
    new_puts = ex.puts - puts0
    # the producing node got it via alias (zero wire crossings); the other
    # node needed exactly one Put — never more, however many reads
    assert new_puts <= 1
    assert ex.refs - refs0 >= 10 - new_puts
    # and the store's transfer ledger saw at most one cross-node pull
    transfers, transfer_bytes = crt.store.transfer_stats()
    assert transfer_bytes >= 0   # ledger is live (exact counts covered above)


def test_transfer_ledger_counts_each_node_once(crt):
    gen = api.task(lambda n: np.full(n, 2.0), name="gen2")
    tot = api.task(lambda a: float(a.sum()), name="tot2")
    part = gen(BIG)
    api.wait_on(part)
    t0, b0 = crt.store.transfer_stats()
    # 12 reads spread over both nodes: at most ONE transfer (to the
    # non-producing node) may be added for this datum
    outs = [tot(part) for _ in range(12)]
    api.wait_on(outs)
    t1, b1 = crt.store.transfer_stats()
    assert t1 - t0 <= 1
    assert b1 - b0 <= BIG * 8


def test_knn_tile_pipeline_matches_oracle(crt):
    """One real KNN tile pipeline across two nodes (CI smoke)."""
    from repro.algorithms import knn

    res = knn.run_knn(n_train=300, n_test=240, d=8, k=3, n_classes=3,
                      train_fragments=4, test_blocks=3)
    expect = knn.reference_knn(n_train=300, n_test=240, d=8, k=3, n_classes=3,
                               train_fragments=4, test_blocks=3)
    np.testing.assert_array_equal(res.predictions, expect)


def test_remote_exception_propagates_with_type(crt):
    def boom(x):
        raise ValueError(f"bad value {x}")

    f = api.task(boom, name="boom")(7)
    with pytest.raises(TaskFailedError) as exc_info:
        api.wait_on(f)
    assert isinstance(exc_info.value.cause, ValueError)
    assert "bad value 7" in str(exc_info.value.cause)


def test_inner_pool_worker_crash_is_contained_and_retryable(crt, tmp_path):
    """A pool-worker death inside an agent respawns inside the agent and
    surfaces as a retryable WorkerCrashedError."""
    flag = str(tmp_path / "poolcrash")

    def crash_once(path):
        if not os.path.exists(path):
            with open(path, "w") as fh:
                fh.write("x")
            os._exit(11)
        return "recovered"

    f = api.task(crash_once, max_retries=3)(flag)
    assert api.wait_on(f) == "recovered"


def test_agent_crash_respawns_and_retries(crt, tmp_path):
    """Killing a whole node agent mid-task surfaces as a retryable
    WorkerCrashedError; the executor respawns the agent and the retry
    re-ships whatever the replacement needs."""
    flag = str(tmp_path / "agentcrash")

    def kill_my_agent_once(path):
        if not os.path.exists(path):
            with open(path, "w") as fh:
                fh.write("x")
            os.kill(os.getppid(), signal.SIGKILL)   # the agent process
        return "recovered"

    restarts0 = crt.executor.agent_restarts
    f = api.task(kill_my_agent_once, max_retries=4)(flag)
    assert api.wait_on(f, timeout=60) == "recovered"
    # under the async control plane (DESIGN.md §18) the respawn runs on
    # the recovery pool concurrently with the retry — the retry lands on
    # the surviving agent, so the replacement may still be registering
    # when wait_on returns.  Bounded poll instead of an instant assert.
    deadline = time.monotonic() + 30.0
    while crt.executor.agent_restarts < restarts0 + 1 \
            and time.monotonic() < deadline:
        time.sleep(0.05)
    assert crt.executor.agent_restarts >= restarts0 + 1


def test_agent_crash_without_retries_is_worker_crashed(crt):
    f = api.task(lambda: os.kill(os.getppid(), signal.SIGKILL),
                 name="die", max_retries=0)()
    with pytest.raises(TaskFailedError) as exc_info:
        api.wait_on(f, timeout=60)
    assert isinstance(exc_info.value.cause, WorkerCrashedError)


def test_closures_cross_the_wire(crt):
    offset = 29
    t = api.task(lambda x: x + offset, name="closured")
    assert api.wait_on(t(13)) == 42


def test_agent_stats_rpc(crt):
    # earlier tests in this module kill agents; under the async control
    # plane the replacement registers on the recovery pool, so give any
    # in-flight respawn a bounded window to land before sampling
    deadline = time.monotonic() + 30.0
    while time.monotonic() < deadline:
        stats = crt.executor.agent_stats()
        live = [s for s in stats if s is not None]
        if len(live) == 2:
            break
        time.sleep(0.05)
    assert len(live) == 2
    for s in live:
        assert s["backend"] == "process"
        assert "plane_entries" in s and "node_id" in s


def test_locality_domains_are_agents(crt):
    # 2 workers per agent → workers 0,1 on node 0 and 2,3 on node 1
    assert [crt.locality_domain(w) for w in range(4)] == [0, 0, 1, 1]


# ------------------------------------------------------------ heavy variants
@pytest.mark.slow
def test_cluster_knn_and_kmeans_heavy():
    """The heavier CI variant: a bigger KNN plus a K-means pipeline on a
    fresh 2-agent cluster (opt-in via -m slow)."""
    from repro.algorithms import kmeans, knn

    api.runtime_start(backend="cluster", n_agents=2, workers_per_node=2)
    try:
        res = knn.run_knn(n_train=2000, n_test=2000, d=30, k=5, n_classes=4,
                          train_fragments=8, test_blocks=8)
        expect = knn.reference_knn(n_train=2000, n_test=2000, d=30, k=5,
                                   n_classes=4, train_fragments=8,
                                   test_blocks=8)
        np.testing.assert_array_equal(res.predictions, expect)
        km = kmeans.run_kmeans(n_points=20_000, d=8, k=4, fragments=8,
                               max_iters=3)
        assert km.centroids.shape == (4, 8)
    finally:
        api.runtime_stop(wait=False)
