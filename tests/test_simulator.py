"""Discrete-event simulator properties (Graham bounds etc.)."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.simulator import CostModel, MachineModel, SimTask, simulate


def random_dag(draw, n):
    tasks = []
    for i in range(n):
        max_deps = min(i, 3)
        k = draw(st.integers(0, max_deps))
        deps = tuple(sorted(set(
            draw(st.integers(0, i - 1)) for _ in range(k)))) if i else ()
        dur = draw(st.floats(0.01, 1.0))
        tasks.append(SimTask(i, f"t{i % 3}", dur, deps))
    return tasks


@settings(max_examples=40, deadline=None)
@given(data=st.data(), n=st.integers(1, 30), workers=st.integers(1, 8))
def test_graham_bounds(data, n, workers):
    """For zero-overhead machines: max(T1/P, Tinf) <= T_P <= T1/P + Tinf."""
    tasks = random_dag(data.draw, n)
    m = MachineModel(n_nodes=1, workers_per_node=workers,
                     ser_Bps=None, dispatch_overhead_s=0.0)
    r = simulate(tasks, m)
    t1 = r.total_work
    tinf = r.critical_path
    assert r.makespan >= max(t1 / workers, tinf) - 1e-9
    assert r.makespan <= t1 / workers + tinf + 1e-9


@settings(max_examples=20, deadline=None)
@given(data=st.data(), n=st.integers(2, 25))
def test_single_worker_equals_total_work(data, n):
    tasks = random_dag(data.draw, n)
    m = MachineModel(n_nodes=1, workers_per_node=1, dispatch_overhead_s=0.0)
    r = simulate(tasks, m)
    assert r.makespan == pytest.approx(r.total_work)


def test_transfer_costs_increase_makespan():
    tasks = [SimTask(0, "a", 0.1, (), out_bytes=10**8),
             SimTask(1, "b", 0.1, (0,), out_bytes=10**8)]
    free = simulate(tasks, MachineModel(n_nodes=1, workers_per_node=2))
    # force cross-node: 2 nodes, 1 worker each; fifo puts b on the idle node
    costly = simulate(tasks, MachineModel(n_nodes=2, workers_per_node=1,
                                          bandwidth_Bps=1e9, ser_Bps=None))
    assert costly.makespan >= free.makespan

def test_locality_policy_avoids_transfers():
    # chain of tasks each producing big outputs: locality scheduling should
    # keep the chain on one node
    tasks = []
    for i in range(8):
        deps = (i - 1,) if i else ()
        tasks.append(SimTask(i, "chain", 0.05, deps, out_bytes=10**9))
    m = MachineModel(n_nodes=2, workers_per_node=1, bandwidth_Bps=1e9,
                     ser_Bps=None)
    r_fifo = simulate(tasks, m, policy="fifo")
    r_loc = simulate(tasks, m, policy="locality")
    assert r_loc.transfer_total <= r_fifo.transfer_total + 1e-9


def test_dispatch_overhead_serializes_launch():
    tasks = [SimTask(i, "x", 0.01, ()) for i in range(64)]
    m0 = MachineModel(n_nodes=1, workers_per_node=64, dispatch_overhead_s=0.0)
    m1 = MachineModel(n_nodes=1, workers_per_node=64, dispatch_overhead_s=0.01)
    assert simulate(tasks, m1).makespan > simulate(tasks, m0).makespan * 5


def test_cost_model_fit():
    cm = CostModel.fit([(100, 1.0), (200, 2.0), (300, 3.0)])
    assert cm(400) == pytest.approx(4.0, rel=1e-6)
    cm2 = CostModel.fit([(10, 0.5)])
    assert cm2(20) == pytest.approx(1.0)


def test_replay_graph_from_real_run():
    from repro.core import api
    from repro.core.simulator import replay_graph

    api.runtime_start(n_workers=2)
    try:
        t = api.task(lambda x: x + 1, name="inc")
        a = t(1)
        b = t(a)
        api.wait_on(b)
        sims = replay_graph(api.current_runtime().graph)
        assert len(sims) == 2
        deps = [s.deps for s in sorted(sims, key=lambda s: s.tid)]
        assert deps[0] == () and len(deps[1]) == 1
        r = simulate(sims, MachineModel())
        assert r.makespan > 0
    finally:
        api.runtime_stop()


def test_cycle_detection():
    tasks = [SimTask(0, "a", 0.1, (1,)), SimTask(1, "b", 0.1, (0,))]
    with pytest.raises(Exception):
        simulate(tasks, MachineModel())
