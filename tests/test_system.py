"""End-to-end behaviour test for the paper's system: the RCOMPSs
programming model executes a real analytics workflow (paper §4/§5 in
miniature), with tracing, and its DAG replayed on a virtual cluster
reproduces the scaling behaviour the paper reports."""
import numpy as np

from repro.algorithms import kmeans
from repro.core import api
from repro.core.simulator import MachineModel, replay_graph, simulate


def test_paper_system_end_to_end():
    api.runtime_start(n_workers=4, policy="locality", tracing=True)
    try:
        res = kmeans.run_kmeans(n_points=6000, d=8, k=5, fragments=8,
                                max_iters=5)
        cref, itref, sseref = kmeans.reference_kmeans(6000, 8, 5, 8, 5, 1e-4)
        np.testing.assert_allclose(res.centroids, cref, atol=1e-8)
        rt = api.current_runtime()
        stats = rt.stats()
        assert stats["tasks_failed"] == 0
        assert stats["tasks_done"] >= 8 + res.iterations * (8 + 7 + 1)
        # trace exists and utilization is sane
        assert 0 < rt.tracer.utilization(4) <= 1.0
        # replay the measured DAG on a virtual machine: the same program
        # scales (the paper's core claim, in miniature)
        sims = replay_graph(rt.graph)
        r1 = simulate(sims, MachineModel(n_nodes=1, workers_per_node=1))
        r8 = simulate(sims, MachineModel(n_nodes=1, workers_per_node=8))
        assert r8.makespan < r1.makespan
        assert r8.makespan >= r1.makespan / 8 - 1e-9
    finally:
        api.runtime_stop()
