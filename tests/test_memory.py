"""Memory governance (DESIGN.md §13): budgets, LRU eviction, spill-to-disk
mmap faulting, memory-aware placement, and out-of-core end-to-end runs.

The acceptance bar: a K-means run whose working set exceeds
``RJAX_MEMORY_BUDGET`` finishes with >0 spills and >0 faults and produces
results bitwise-equal to the unbounded run, on every backend.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.algorithms import kmeans
from repro.core import api
from repro.core.dag import TaskGraph, TaskNode
from repro.core.executors import SHM_MIN_BYTES, SegmentPlane
from repro.core.futures import ObjectStore
from repro.core.memory import (
    LRULedger,
    MemoryBudget,
    MemoryGovernor,
    SpilledValue,
    parse_bytes,
    spill_to_file,
    spillable,
)
from repro.core.scheduler import Scheduler


# ------------------------------------------------------------- parse_bytes
def test_parse_bytes_units_and_unbounded():
    assert parse_bytes("256M") == 256 << 20
    assert parse_bytes("1g") == 1 << 30
    assert parse_bytes("1.5k") == 1536
    assert parse_bytes("4096") == 4096
    assert parse_bytes(1 << 20) == 1 << 20
    assert parse_bytes("64kb") == 64 << 10
    # None / 0 / empty mean "unbounded"
    assert parse_bytes(None) is None
    assert parse_bytes(0) is None
    assert parse_bytes("0") is None
    assert parse_bytes("") is None
    with pytest.raises(ValueError):
        parse_bytes("12 parsecs")
    with pytest.raises(ValueError):
        parse_bytes(-1)


# ------------------------------------------------------------ budget maths
def test_budget_watermarks_and_ledger():
    b = MemoryBudget(1000, high_frac=0.9, low_frac=0.5)
    b.charge(800)
    assert not b.over_high()           # 800 <= 900
    b.charge(150)
    assert b.over_high()               # 950 > 900
    assert b.release_target() == 450   # down to 500
    b.discharge(500)
    assert b.release_target() == 0
    b.note_spill(100)
    b.note_fault(100)
    s = b.stats()
    assert s["spills"] == 1 and s["faults"] == 1
    assert s["spill_bytes"] == 100 and s["fault_bytes"] == 100


def test_lru_order_pins_and_victims():
    led = LRULedger()
    led.add((1, 1), 100)
    led.add((2, 1), 100)
    led.add((3, 1), 100)
    led.touch((1, 1))                        # (2,1) is now coldest
    assert [k for k, _ in led.victims(150)] == [(2, 1), (3, 1)]
    led.pin((2, 1))
    assert [k for k, _ in led.victims(150)] == [(3, 1), (1, 1)]
    led.pin((2, 1))
    led.unpin((2, 1))
    assert led.pinned((2, 1))                # pin counts nest
    led.unpin((2, 1))
    assert not led.pinned((2, 1))
    assert led.discard((3, 1)) == 100
    assert (3, 1) not in led


def test_governor_soft_bound_when_everything_pinned():
    spilled = []
    gov = MemoryGovernor(MemoryBudget(100, high_frac=0.5, low_frac=0.3),
                         lambda key: spilled.append(key) or 10)
    gov.pin_many([(1, 1)])
    gov.admit((1, 1), 200)          # over high, but the only entry is pinned
    assert spilled == []            # progress beats the watermark
    gov.unpin_many([(1, 1)])
    gov.admit((2, 1), 10)           # (1,1) now evictable
    assert (1, 1) in spilled


# ------------------------------------------- store spill/fault round trips
def _governed_store(budget, tmp_path, min_bytes=0):
    store = ObjectStore()
    store.configure_memory(budget, spill_dir=str(tmp_path),
                           min_bytes=min_bytes)
    return store


@pytest.mark.parametrize("make", [
    lambda: np.array(3.5),                                   # 0-d
    lambda: np.asfortranarray(np.arange(24.0).reshape(4, 6)),  # F-order
    lambda: np.arange(64, dtype=np.int32).reshape(8, 8)[::2, ::2],  # strided
    lambda: np.arange(100, dtype=np.uint16),
])
def test_spill_fault_roundtrip_preserves_values(tmp_path, make):
    """0-d, Fortran-order, and strided arrays must survive the
    spill → fault round trip bit-for-bit (shape, dtype, contents)."""
    store = _governed_store(64, tmp_path)   # tiny: everything spills
    arr = make()
    store.put((1, 1), arr, node=0)
    store.put((2, 1), np.zeros(1024), node=0)  # pushes (1,1) past the mark
    assert store.memory_stats()["spills"] >= 1
    back = store.get_nowait((1, 1))
    assert store.memory_stats()["faults"] >= 1
    assert isinstance(back, np.memmap)
    assert back.shape == arr.shape and back.dtype == arr.dtype
    assert np.array_equal(back, np.ascontiguousarray(arr).reshape(arr.shape))


def test_reader_view_survives_full_eviction(tmp_path):
    """A reader holding a faulted view keeps a valid array even after the
    store evicts the entry entirely and the spill file is unlinked (POSIX
    keeps the mapping alive until the last reference drops)."""
    store = _governed_store(4096, tmp_path)
    arr = np.arange(2048, dtype=np.float64)
    store.put((1, 1), arr, node=0)
    store.put((2, 1), np.ones(4096), node=0)   # evicts (1,1) to disk
    view = store.get_nowait((1, 1))            # faulted memmap view
    path = view.filename
    assert os.path.exists(path)
    store.evict((1, 1))                        # full eviction unlinks
    del store
    assert np.array_equal(view, arr)           # mapping still valid
    checksum = float(np.sum(view))
    assert checksum == float(np.sum(arr))


def test_spilled_entry_evicted_without_fault_unlinks_file(tmp_path):
    store = _governed_store(64, tmp_path)
    store.put((1, 1), np.arange(512, dtype=np.float64), node=0)
    store.put((2, 1), np.ones(512), node=0)
    spilled = store._values[(1, 1)]
    assert isinstance(spilled, SpilledValue)
    assert os.path.exists(spilled.path)
    store.evict((1, 1))
    assert not os.path.exists(spilled.path)


def test_spillable_excludes_memmaps_and_objects():
    assert spillable(np.zeros(4096))
    assert not spillable(np.zeros(4096, dtype=object), min_bytes=0)
    assert not spillable([1, 2, 3])
    back = spill_to_file(np.zeros(4096)).load()
    assert not spillable(back)   # already file-backed: never re-spilled


# ------------------------------------------------- node budget bookkeeping
def test_node_bytes_tracking_and_forget_node_resets_ledger():
    """Residency reset after an agent respawn must also reset that node's
    budget ledger, or placement starves the fresh (empty) node."""
    store = ObjectStore()
    a = np.zeros(1000, dtype=np.uint8)
    store.put((1, 1), a, node=0)
    store.note_location((1, 1), 1)
    store.put((2, 1), np.zeros(500, dtype=np.uint8), node=1)
    assert store.node_bytes(0) == 1000
    assert store.node_bytes(1) == 1500
    store.forget_node(1)
    assert store.node_bytes(1) == 0
    assert store.locations((1, 1)) == {0}
    store.evict((1, 1))
    assert store.node_bytes(0) == 0


# ------------------------------------------------ memory-aware placement
def _mk_sched(node_budget=None, workers_per_node=1):
    graph = TaskGraph()
    store = ObjectStore()
    sched = Scheduler(graph, store, policy="locality",
                      workers_per_node=workers_per_node,
                      node_budget=node_budget)
    return sched, graph, store


def _add_task(graph, store, dep_nbytes_by_node, name="t"):
    tid = graph.next_task_id()
    dep_keys = set()
    for node, nbytes in dep_nbytes_by_node:
        did = store.new_data_id()
        key = (did, 1)
        store.put(key, np.zeros(max(0, nbytes), dtype=np.uint8), node=node)
        dep_keys.add(key)
    graph.add_task(TaskNode(task_id=tid, name=name, fn=lambda: None,
                            args=(), kwargs={}, dep_keys=dep_keys,
                            out_keys=[]))
    return tid


def test_placement_prefers_headroom_over_pure_locality():
    """A fully-local task whose projected output mostly cannot fit on
    this node scores below a remote-input task that fits: tasks flow to
    nodes with both the data and the headroom."""
    budget = 1 << 20
    sched, graph, store = _mk_sched(node_budget=budget)
    # node 0 is mostly full: ~260 KB of headroom left after the filler
    # and task A's resident input
    store.put((500, 1), np.zeros(700 << 10, dtype=np.uint8), node=0)
    # task A: input local to node 0, but its outputs are known to be
    # ~1 MB — more than 2/3 of that projection overflows the headroom
    a = _add_task(graph, store, [(0, 64 << 10)], name="big_out")
    sched.note_output_bytes("big_out", 1 << 20)
    # task B: input lives on node 1 (remote for worker 0), small output —
    # its ~128 KB transfer fits node 0's headroom
    b = _add_task(graph, store, [(1, 128 << 10)], name="small_out")
    sched.note_output_bytes("small_out", 1024)
    sched.push_many([a, b])
    # pure locality would hand worker 0 task A (score 1.0 vs 0.0); the
    # memory-aware score penalizes A's overflow below B's small,
    # affordable transfer
    assert sched.take(0, timeout=0.1) == b
    # worker 1 (node 1, has headroom) then takes A
    assert sched.take(1, timeout=0.1) == a


def test_placement_without_budget_is_pure_locality():
    sched, graph, store = _mk_sched(node_budget=None)
    store.put((500, 1), np.zeros(1 << 20, dtype=np.uint8), node=0)
    a = _add_task(graph, store, [(0, 1 << 18)], name="big_out")
    sched.note_output_bytes("big_out", 1 << 19)
    b = _add_task(graph, store, [(1, 1 << 18)], name="small_out")
    sched.push_many([a, b])
    assert sched.take(0, timeout=0.1) == a   # unbounded: locality wins


def test_progress_beats_budget_when_every_choice_overflows():
    """The budget is a gradient, not an admission check: a worker with
    only overflowing candidates still takes one."""
    sched, graph, store = _mk_sched(node_budget=4096)
    store.put((500, 1), np.zeros(4096, dtype=np.uint8), node=0)
    a = _add_task(graph, store, [(1, 1 << 20)], name="huge")
    sched.push_many([a])
    assert sched.take(0, timeout=0.1) == a


# ------------------------------------------------- segment-plane eviction
@pytest.mark.skipif(os.environ.get("RJAX_MP_CONTEXT") == "spawn",
                    reason="plane unit test independent of start method")
def test_segment_plane_evicts_cold_and_counts_faults():
    nbytes = max(SHM_MIN_BYTES, 1 << 16)
    plane = SegmentPlane(memory_budget=int(nbytes * 2.2))
    evicted_names = []
    plane.on_evict = evicted_names.append
    arrs = {k: np.full(nbytes // 8, float(k)) for k in (1, 2, 3)}
    try:
        plane.ensure((1, 1), arrs[1])
        plane.ensure((2, 1), arrs[2])
        plane.ensure((3, 1), arrs[3])          # crosses the high mark
        stats = plane.stats()
        assert stats["plane_spills"] >= 1
        assert len(evicted_names) == stats["plane_spills"]
        # re-planing an evicted key is a fault, and pinned keys survive
        plane.governor.pin_many([(2, 1), (3, 1)])
        plane.ensure((1, 1), arrs[1])
        stats = plane.stats()
        assert stats["plane_faults"] >= 1 or (1, 1) in plane._by_key
        plane.governor.unpin_many([(2, 1), (3, 1)])
    finally:
        plane.close()


def test_segment_plane_pinned_keys_never_evicted():
    nbytes = max(SHM_MIN_BYTES, 1 << 16)
    plane = SegmentPlane(memory_budget=int(nbytes * 1.5))
    try:
        plane.governor.pin_many([(1, 1)])
        plane.ensure((1, 1), np.ones(nbytes // 8))
        plane.ensure((2, 1), np.ones(nbytes // 8))
        plane.ensure((3, 1), np.ones(nbytes // 8))
        assert (1, 1) in plane._by_key   # over budget, but pinned
        plane.governor.unpin_many([(1, 1)])
    finally:
        plane.close()


# ----------------------------------------------------- end-to-end, bounded
def _oob_kmeans(backend, budget, tmp_path, **kw):
    rt = api.runtime_start(n_workers=2, backend=backend, policy="locality",
                           memory_budget=budget, tracing=False,
                           spill_dir=str(tmp_path), **kw)
    try:
        res = kmeans.run_kmeans(n_points=16000, d=10, k=4, fragments=8,
                                max_iters=4, seed=0)
        return res, rt.stats()
    finally:
        api.runtime_stop(wait=False)


@pytest.mark.parametrize("backend", ["thread", "process"])
def test_out_of_core_kmeans_matches_unbounded(tmp_path, backend):
    """Working set (8 × 160 KB fragments) over a 400 KB budget: the run
    must finish, spill, fault, and match the unbounded result bitwise."""
    ref, ref_stats = _oob_kmeans(backend, None, tmp_path)
    assert ref_stats["memory"]["spills"] == 0
    res, stats = _oob_kmeans(backend, "400K", tmp_path)
    mem = stats["memory"]
    assert mem["spills"] > 0 and mem["faults"] > 0
    assert np.array_equal(ref.centroids, res.centroids)
    assert ref.iterations == res.iterations
    assert ref.sse == res.sse
    if backend == "process":
        ex = stats["executor"]
        assert ex["plane_spills"] > 0 and ex["plane_faults"] > 0


def test_out_of_core_kmeans_cluster_backend(tmp_path, monkeypatch):
    """Same bar on the real TCP cluster: scheduler store AND node-agent
    planes spill/fault, results bitwise-equal to the unbounded run.

    Runs with the peer data plane OFF (RJAX_P2P=0): this test covers the
    scheduler store's governance, and under §15 intermediate results
    never enter the scheduler store at all (the governed-p2p variant
    lives in test_p2p.py::test_out_of_core_under_p2p)."""
    monkeypatch.setenv("RJAX_P2P", "0")
    monkeypatch.setenv("RJAX_INLINE_MAX", "0")
    rt = api.runtime_start(backend="cluster", n_agents=2, workers_per_node=1,
                           policy="locality", tracing=False)
    try:
        ref = kmeans.run_kmeans(n_points=16000, d=10, k=4, fragments=8,
                                max_iters=4, seed=0)
    finally:
        api.runtime_stop(wait=False)

    rt = api.runtime_start(backend="cluster", n_agents=2, workers_per_node=1,
                           policy="locality", memory_budget="400K",
                           spill_dir=str(tmp_path), tracing=False)
    try:
        res = kmeans.run_kmeans(n_points=16000, d=10, k=4, fragments=8,
                                max_iters=4, seed=0)
        stats = rt.stats()
        agents = rt.executor.agent_stats()
    finally:
        api.runtime_stop(wait=False)
    mem = stats["memory"]
    assert mem["spills"] > 0 and mem["faults"] > 0
    node_spills = sum((s or {}).get("plane_spills", 0) for s in agents)
    node_faults = sum((s or {}).get("plane_faults", 0) for s in agents)
    assert node_spills > 0 and node_faults > 0
    assert np.array_equal(ref.centroids, res.centroids)
    assert ref.sse == res.sse


def test_env_knob_reaches_runtime(monkeypatch, tmp_path):
    monkeypatch.setenv("RJAX_MEMORY_BUDGET", "1M")
    rt = api.runtime_start(n_workers=2, tracing=False,
                           spill_dir=str(tmp_path))
    try:
        assert rt.memory_budget == 1 << 20
        assert rt.store.governor is not None
        assert rt.scheduler.node_budget == 1 << 20
    finally:
        api.runtime_stop(wait=False)
