"""Assignment-required smoke tests: every architecture instantiates a
REDUCED config and runs one forward/train step (+ a decode step) on CPU,
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import (
    ARCH_IDS,
    SMOKE_SHAPES,
    get_config,
    make_batch,
    shape_applicable,
)
from repro.models.lm import forward, init_params, loss_fn

# minutes of JAX compile+run on CPU: opt-in via `-m slow` (see pytest.ini)
pytestmark = pytest.mark.slow



@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = make_batch(cfg, SMOKE_SHAPES["train_4k"])
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, b["batch"]), has_aux=True)(params)
    assert jnp.isfinite(loss), arch
    assert all(jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_smoke(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    shape = SMOKE_SHAPES["prefill_32k"]
    b = make_batch(cfg, shape)
    logits, caches, _ = forward(cfg, params, b["batch"],
                                make_cache_len=shape.seq, last_only=True)
    assert logits.shape == (shape.batch, 1, cfg.vocab_size), arch
    assert jnp.all(jnp.isfinite(logits)), arch
    assert caches is not None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    d = make_batch(cfg, SMOKE_SHAPES["decode_32k"])
    logits, new_caches, _ = forward(cfg, params, d["batch"],
                                    caches=d["caches"], pos_offset=d["pos"])
    assert logits.shape[0] == SMOKE_SHAPES["decode_32k"].batch
    assert logits.shape[1] == 1 and logits.shape[2] == cfg.vocab_size
    assert jnp.all(jnp.isfinite(logits)), arch
    assert jax.tree.structure(new_caches) == jax.tree.structure(d["caches"])


@pytest.mark.parametrize("arch", ["mamba2-780m", "recurrentgemma-9b"])
def test_long_context_smoke_for_subquadratic(arch):
    cfg = get_config(arch, reduced=True)
    assert shape_applicable(get_config(arch), "long_500k")
    params = init_params(cfg, jax.random.PRNGKey(0))
    d = make_batch(cfg, SMOKE_SHAPES["long_500k"])
    logits, _, _ = forward(cfg, params, d["batch"], caches=d["caches"],
                           pos_offset=d["pos"])
    assert jnp.all(jnp.isfinite(logits)), arch


def test_full_attention_archs_skip_long_500k():
    for arch in ARCH_IDS:
        full = get_config(arch)
        expect = arch in ("mamba2-780m", "recurrentgemma-9b")
        assert shape_applicable(full, "long_500k") == expect, arch


def test_exact_published_configs():
    """Spot-check the FULL configs against the assignment table."""
    g = get_config("granite-20b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab_size) == (52, 6144, 48, 1, 24576, 49152)
    q = get_config("qwen3-moe-235b-a22b")
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.n_experts,
            q.top_k, q.vocab_size) == (94, 4096, 64, 4, 128, 8, 151936)
    d = get_config("deepseek-moe-16b")
    assert (d.n_experts, d.top_k, d.n_shared_experts, d.d_ff_expert) == \
        (64, 6, 2, 1408)
    m = get_config("mamba2-780m")
    assert (m.n_layers, m.d_model, m.ssm_state, m.vocab_size) == \
        (48, 1536, 128, 50280)
    r = get_config("recurrentgemma-9b")
    assert (r.n_layers, r.d_model, r.vocab_size, r.local_window) == \
        (38, 4096, 256000, 2048)
    assert r.block_pattern == ("rglru", "rglru", "local_attn")
