"""Wire-protocol property tests (DESIGN.md §12): framing survives partial
reads/short writes, >4 GiB length fields, back-to-back messages, and cut
connections surface as retryable errors."""
import socket
import struct
import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster.channel import AgentChannel
from repro.cluster.protocol import (
    ConnectionClosed,
    Put,
    Ref,
    array_frame,
    frame_to_array,
    pack_payload,
    recv_msg,
    send_msg,
    unpack_payload,
)


class TrickleSocket:
    """A fake socket that fragments every transfer: sendall is chopped into
    tiny writes and recv_into returns at most ``chunk`` bytes — the
    adversarial TCP segmentation the framing layer must survive."""

    def __init__(self, chunk: int = 3):
        self.buf = bytearray()
        self.chunk = chunk
        self.closed = False

    def sendall(self, data) -> None:
        data = bytes(data)
        for i in range(0, len(data), self.chunk):   # short writes
            self.buf.extend(data[i:i + self.chunk])

    def recv_into(self, view) -> int:
        if not self.buf:
            if self.closed:
                return 0
            raise AssertionError("reader starved (protocol desync)")
        n = min(len(view), self.chunk, len(self.buf))   # partial reads
        view[:n] = self.buf[:n]
        del self.buf[:n]
        return n


def test_roundtrip_under_partial_reads_and_short_writes():
    s = TrickleSocket(chunk=3)
    arr = np.arange(997, dtype=np.float64)   # odd size: never chunk-aligned
    send_msg(s, {"op": "task", "n": 42}, [array_frame(arr)])
    meta, frames = recv_msg(s)
    assert meta == {"op": "task", "n": 42}
    np.testing.assert_array_equal(frame_to_array(frames[0]), arr)


def test_interleaved_messages_decode_in_order():
    s = TrickleSocket(chunk=7)
    a = np.ones(130, dtype=np.float32)
    b = np.arange(9, dtype=np.int64)
    send_msg(s, {"mid": 1}, [array_frame(a)])
    send_msg(s, {"mid": 2}, [array_frame(b), array_frame(a)])
    send_msg(s, {"mid": 3})
    m1, f1 = recv_msg(s)
    m2, f2 = recv_msg(s)
    m3, f3 = recv_msg(s)
    assert [m["mid"] for m in (m1, m2, m3)] == [1, 2, 3]
    np.testing.assert_array_equal(frame_to_array(f1[0]), a)
    np.testing.assert_array_equal(frame_to_array(f2[0]), b)
    np.testing.assert_array_equal(frame_to_array(f2[1]), a)
    assert f3 == []


def test_length_fields_are_64_bit():
    """Frames beyond the u32 ceiling must be representable.  We pack the
    header for a >4 GiB frame directly (allocating one would be rude) and
    check the length survives."""
    big = 2**32 + 12345
    header = struct.pack("<4sQ", b"RJW1", 2) + struct.pack("<2Q", 10, big)
    magic, n = struct.unpack_from("<4sQ", header)
    lens = struct.unpack_from("<2Q", header, 12)
    assert magic == b"RJW1" and n == 2
    assert lens == (10, big)


def test_truncated_stream_raises_connection_closed():
    s = TrickleSocket(chunk=5)
    arr = np.ones(64)
    send_msg(s, {"mid": 1}, [array_frame(arr)])
    # cut the stream mid-frame: drop the tail, then "close" the socket
    del s.buf[len(s.buf) // 2:]
    s.closed = True
    with pytest.raises(ConnectionClosed) as exc_info:
        recv_msg(s)
    assert exc_info.value.mid_message


def test_clean_close_between_messages_is_not_mid_message():
    s = TrickleSocket()
    s.closed = True
    with pytest.raises(ConnectionClosed) as exc_info:
        recv_msg(s)
    assert not exc_info.value.mid_message


@settings(max_examples=20, deadline=None)
@given(
    shape=st.lists(st.integers(1, 6), min_size=0, max_size=3),
    dtype=st.sampled_from(["f4", "f8", "i4", "i8", "u1", "u2"]),
    chunk=st.integers(1, 13),
)
def test_frame_roundtrip_property(shape, dtype, chunk):
    arr = (np.random.standard_normal(tuple(shape)) * 50).astype(np.dtype(dtype))
    s = TrickleSocket(chunk=chunk)
    send_msg(s, {"k": "v"}, [array_frame(arr)])
    _, frames = recv_msg(s)
    out = frame_to_array(frames[0])
    np.testing.assert_array_equal(out, arr)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    assert not out.flags.writeable


def test_noncontiguous_frames_copy_on_encode():
    strided = np.arange(256.0).reshape(16, 16)[:, ::2]
    fortran = np.asfortranarray(np.arange(30.0).reshape(5, 6))
    zero_d = np.array(7.25)
    s = TrickleSocket(chunk=9)
    send_msg(s, {}, [array_frame(strided), array_frame(fortran),
                     array_frame(zero_d)])
    _, frames = recv_msg(s)
    np.testing.assert_array_equal(frame_to_array(frames[0]), strided)
    np.testing.assert_array_equal(frame_to_array(frames[1]), fortran)
    np.testing.assert_array_equal(frame_to_array(frames[2]), zero_d)


# ---------------------------------------------------------- payload packing
def test_pack_payload_put_then_ref():
    arr = np.ones(512, dtype=np.float64)
    key = (3, 1)
    resident = set()
    structure, frames, info = pack_payload(([arr], {}), {id(arr): key}, resident)
    assert info["put_keys"] == [key] and info["refs"] == 0
    assert isinstance(structure[0][0], Put)
    resident.add(key)
    structure2, frames2, info2 = pack_payload(([arr], {}), {id(arr): key}, resident)
    assert isinstance(structure2[0][0], Ref)
    assert info2["refs"] == 1 and frames2 == []   # reuse-many: no bytes

    plane = {}
    out, _ = unpack_payload(structure, frames, lookup=plane.get,
                            store=plane.__setitem__)
    np.testing.assert_array_equal(out[0], arr)
    out2, _ = unpack_payload(structure2, frames2, lookup=plane.get,
                             store=plane.__setitem__)
    assert out2[0] is plane[key]


def test_pack_payload_dedups_within_one_message():
    """The same keyed datum appearing twice in one call ships once: first
    occurrence is the Put, later ones are Refs against it."""
    arr = np.ones(512, dtype=np.float64)
    key = (5, 1)
    structure, frames, info = pack_payload(
        ([arr, arr], {"again": arr}), {id(arr): key}, set())
    assert info["put_keys"] == [key] and info["refs"] == 2
    assert len(frames) == 1
    plane = {}
    out, kw = unpack_payload(structure, frames, lookup=plane.get,
                             store=plane.__setitem__)
    np.testing.assert_array_equal(out[0], arr)
    assert out[1] is plane[key] and kw["again"] is plane[key]


def test_pack_payload_inlines_small_anonymous_values():
    small = np.ones(4)
    structure, frames, _ = pack_payload(([small, "txt", 5], {}), {}, set())
    assert frames == []               # rides the metadata pickle
    out, _ = unpack_payload(structure, frames)
    np.testing.assert_array_equal(out[0], small)
    assert out[1:] == ["txt", 5]


def test_pack_payload_object_dtype_keyed_inline():
    arr = np.array([{"a": 1}, None], dtype=object)
    key = (9, 1)
    structure, frames, info = pack_payload(([arr], {}), {id(arr): key}, set())
    assert frames == [] and info["put_keys"] == [key]
    plane = {}
    out, _ = unpack_payload(structure, frames, lookup=plane.get,
                            store=plane.__setitem__)
    assert out[0][0] == {"a": 1} and key in plane


# ------------------------------------------------------- channel disconnects
def test_agent_disconnect_mid_request_fails_pending():
    """A peer that dies mid-conversation must fail the in-flight request
    with ConnectionClosed (which the cluster executor maps to a retryable
    WorkerCrashedError)."""
    server, client = socket.socketpair()
    ch = AgentChannel(client, node_id=0, hello={"workers": 1})

    def half_reply_then_die():
        recv_msg(server)                     # consume the request
        server.sendall(b"RJW1\x02")          # start a reply, then vanish
        server.close()

    t = threading.Thread(target=half_reply_then_die)
    t.start()
    with pytest.raises(ConnectionClosed):
        ch.request({"op": "stats"}, timeout=10.0)
    t.join()
    ch.close()


def test_channel_refuses_after_close():
    server, client = socket.socketpair()
    ch = AgentChannel(client, node_id=1, hello={})
    ch.close()
    server.close()
    with pytest.raises(ConnectionClosed):
        ch.request({"op": "stats"}, timeout=5.0)


# ------------------------------------------------ wire coalescing (§14)
class CountingSocket(TrickleSocket):
    """Records each sendall call so tests can assert syscall batching."""

    def __init__(self, chunk: int = 1 << 20):
        super().__init__(chunk=chunk)
        self.sends = []

    def sendall(self, data) -> None:
        self.sends.append(len(bytes(data)))
        super().sendall(data)


def test_small_message_coalesces_into_one_send():
    """A task message whose frames are all small rides ONE sendall — one
    packet under TCP_NODELAY instead of one per header/meta/frame part."""
    s = CountingSocket()
    small = [np.arange(16, dtype=np.float64) for _ in range(4)]
    keys = {id(a): (i + 1, 1) for i, a in enumerate(small)}
    structure, frames, info = pack_payload(tuple(small), keys, set())
    send_msg(s, {"op": "task", "structure": structure}, frames)
    assert len(s.sends) == 1
    meta, rframes = recv_msg(s)
    got = unpack_payload(meta["structure"], rframes,
                         lookup={}.get, store=lambda k, v: None)
    for want, g in zip(small, got):
        np.testing.assert_array_equal(g, want)


def test_large_frames_bypass_coalescing_but_roundtrip():
    from repro.cluster.protocol import WIRE_COALESCE_MAX

    s = CountingSocket()
    big = np.arange(WIRE_COALESCE_MAX // 8 + 128, dtype=np.float64)
    send_msg(s, {"op": "task"}, [array_frame(big)])
    assert len(s.sends) > 1           # zero-copy path: big buffer separate
    meta, frames = recv_msg(s)
    np.testing.assert_array_equal(frame_to_array(frames[0]), big)


def test_coalesced_stream_preserves_put_before_ref_fifo():
    """The §12 pre-store guarantee under §14 batching: a pipelined stream
    of task messages where later messages Ref keys Put by earlier ones
    must resolve when processed in wire-FIFO order — byte-identical
    semantics whether or not the messages were coalesced."""
    s = CountingSocket()
    arr = np.arange(64, dtype=np.float64)
    key = (7, 1)
    resident = set()
    st1, f1, info1 = pack_payload((arr,), {id(arr): key}, resident)
    resident.update(info1["put_keys"])            # marked at send time
    st2, f2, info2 = pack_payload((arr,), {id(arr): key}, resident)
    send_msg(s, {"mid": 1, "structure": st1}, f1)
    send_msg(s, {"mid": 2, "structure": st2}, f2)
    assert info1["put_keys"] == [key] and info2["refs"] == 1
    plane = {}
    for want_mid in (1, 2):
        meta, frames = recv_msg(s)
        assert meta["mid"] == want_mid
        (got,) = unpack_payload(meta["structure"], frames,
                                lookup=lambda k: plane[k],
                                store=plane.__setitem__)
        np.testing.assert_array_equal(got, arr)
    assert list(plane) == [key]


# ---------------------------------------------------- §15 peer data plane
def test_pack_payload_remote_value_becomes_fetch_then_ref():
    """A RemoteValue input turns into a Fetch directive on first ship to
    a node and a Ref ever after — the scheduler moves metadata only."""
    from repro.cluster.protocol import Fetch
    from repro.core.futures import RemoteValue

    rv = RemoteValue(token=9, node=0, addr="127.0.0.1:4242", nbytes=8192,
                     key=(3, 1))
    resident = set()
    st, frames, info = pack_payload((rv,), {id(rv): (3, 1)}, resident)
    assert frames == []                       # no bytes on the scheduler link
    assert isinstance(st[0], Fetch)
    assert st[0].key == (3, 1) and st[0].token == 9
    assert st[0].addr == "127.0.0.1:4242" and st[0].nbytes == 8192
    assert info["fetch_keys"] == [(3, 1)] and info["fetch_bytes"] == 8192
    resident.update(info["fetch_keys"])       # marked at send time
    st2, _, info2 = pack_payload((rv,), {id(rv): (3, 1)}, resident)
    assert isinstance(st2[0], Ref) and info2["refs"] == 1


def test_fetch_marker_pickles_through_the_wire():
    from repro.cluster.protocol import Fetch

    s = CountingSocket()
    f = Fetch((5, 2), 77, 1, "10.0.0.1:9999", 1 << 20)
    send_msg(s, {"structure": [f]})
    meta, _ = recv_msg(s)
    g = meta["structure"][0]
    assert (g.key, g.token, g.node, g.addr, g.nbytes) == \
        ((5, 2), 77, 1, "10.0.0.1:9999", 1 << 20)


def test_remote_ref_pickles_and_carries_descriptor_only():
    from repro.cluster.protocol import RemoteRef

    s = CountingSocket()
    send_msg(s, {"structure": RemoteRef(12, 65536), "tokens": []})
    meta, frames = recv_msg(s)
    assert frames == []
    rr = meta["structure"]
    assert rr.token == 12 and rr.nbytes == 65536


def test_pack_payload_keys_tuple_datums():
    """Datum-level keying (§15): a tuple-valued datum is ONE Put whose
    inner arrays ride frames, and a Ref on re-ship."""
    big = np.arange(2048, dtype=np.float64)
    datum = (big, np.ones(4), "label")
    key = (11, 1)
    resident = set()
    st, frames, info = pack_payload((datum,), {id(datum): key}, resident)
    assert isinstance(st[0], Put) and st[0].key == key
    assert len(frames) == 1                   # only the big array framed
    assert info["put_keys"] == [key]
    assert info["put_bytes"] == big.nbytes + 32
    plane = {}
    (out,) = unpack_payload(st, frames, lookup=lambda k: plane[k],
                            store=plane.__setitem__)
    np.testing.assert_array_equal(out[0], big)
    np.testing.assert_array_equal(out[1], np.ones(4))
    assert out[2] == "label"
    st2, f2, info2 = pack_payload((datum,), {id(datum): key}, {key})
    assert isinstance(st2[0], Ref) and not f2 and info2["refs"] == 1


def test_frame_eligible_min_bytes_threshold():
    from repro.cluster.protocol import frame_eligible

    small = np.ones(4)
    assert frame_eligible(small)
    assert not frame_eligible(small, min_bytes=1024)
    assert frame_eligible(np.ones(1024), min_bytes=1024)


def test_datum_frame_bytes_sums_eligible_arrays():
    from repro.cluster.protocol import datum_frame_bytes

    datum = {"x": np.ones(8), "y": (np.zeros(4), "txt", 3)}
    assert datum_frame_bytes(datum) == 8 * 8 + 4 * 8
    assert datum_frame_bytes("scalar") == 0
