"""Paper §4 algorithms: runtime execution vs single-shot numpy oracles."""
import numpy as np
import pytest

from repro.algorithms import kmeans, knn, linreg
from repro.algorithms.common import tree_reduce, tree_reduce_spec
from repro.core import api
from repro.core.simulator import MachineModel, simulate


@pytest.fixture()
def rt():
    api.runtime_start(n_workers=4)
    yield
    api.runtime_stop(wait=False)


def test_knn_matches_oracle(rt):
    res = knn.run_knn(n_train=400, n_test=300, d=16, k=5, n_classes=4,
                      train_fragments=4, test_blocks=3)
    ref = knn.reference_knn(400, 300, 16, 5, 4, 4, 3)
    np.testing.assert_array_equal(res.predictions, ref)


def test_knn_merge_arity(rt):
    r2 = knn.run_knn(n_train=300, n_test=100, d=8, k=3, train_fragments=5,
                     merge_arity=2)
    r3 = knn.run_knn(n_train=300, n_test=100, d=8, k=3, train_fragments=5,
                     merge_arity=3)
    np.testing.assert_array_equal(r2.predictions, r3.predictions)


def test_knn_accuracy_on_separated_blobs(rt):
    res = knn.run_knn(n_train=600, n_test=300, d=8, k=5, n_classes=3,
                      train_fragments=3)
    X, y = knn.knn_fill_fragment(0, 600, 8, 3)
    assert res.predictions.shape == (300,)
    assert set(np.unique(res.predictions)) <= {0, 1, 2}


def test_kmeans_matches_oracle(rt):
    res = kmeans.run_kmeans(n_points=3000, d=6, k=5, fragments=4, max_iters=7)
    cref, itref, sseref = kmeans.reference_kmeans(3000, 6, 5, 4, 7, 1e-4)
    assert res.iterations == itref
    np.testing.assert_allclose(res.centroids, cref, atol=1e-8)
    assert res.sse == pytest.approx(sseref, rel=1e-10)


def test_kmeans_sse_monotone(rt):
    res = kmeans.run_kmeans(n_points=4000, d=4, k=6, fragments=4, max_iters=10)
    # WCSS is non-increasing across Lloyd iterations => shifts shrink overall
    assert res.shifts[-1] <= res.shifts[0]


def test_linreg_matches_oracle(rt):
    res = linreg.run_linreg(n_rows=3000, p=20, n_pred=400, fragments=4,
                            pred_blocks=2)
    bref, pref = linreg.reference_linreg(3000, 20, 400, 4, 2)
    np.testing.assert_allclose(res.beta, bref, atol=1e-8)
    np.testing.assert_allclose(res.predictions, pref, atol=1e-8)


def test_linreg_recovers_ground_truth(rt):
    res = linreg.run_linreg(n_rows=8000, p=10, n_pred=100, fragments=4)
    truth = np.random.default_rng(1234).standard_normal(11)
    np.testing.assert_allclose(res.beta, truth, atol=0.05)


def test_tree_reduce_plain_values():
    assert tree_reduce(list(range(10)), lambda a, b: a + b) == 45
    assert tree_reduce([5], lambda a, b: a + b) == 5
    merges = tree_reduce_spec(5, arity=2)
    assert len(merges) == 4  # n-1 merges


@pytest.mark.parametrize("algo,calib,spec,kw", [
    (knn, lambda: knn.calibrate(d=8, k=3, units=(200, 400)),
     lambda c: knn.dag_spec(c, 2000, 4000, 8, 3, train_fragments=8,
                            test_blocks=4), {}),
    (kmeans, lambda: kmeans.calibrate(d=8, k=4, units=(500, 1000)),
     lambda c: kmeans.dag_spec(c, 32000, 8, 4, fragments=16, iterations=2), {}),
    (linreg, lambda: linreg.calibrate(p=16, units=(500, 1000)),
     lambda c: linreg.dag_spec(c, 32000, 16, 4000, fragments=16,
                               pred_blocks=4), {}),
])
def test_dag_specs_simulate(algo, calib, spec, kw):
    costs = calib()
    tasks = spec(costs)
    r1 = simulate(tasks, MachineModel(n_nodes=1, workers_per_node=1))
    r8 = simulate(tasks, MachineModel(n_nodes=1, workers_per_node=8))
    assert r8.makespan <= r1.makespan + 1e-9
    assert r1.makespan == pytest.approx(r1.total_work)


def test_scaling_efficiency_reasonable():
    """The DES reproduces the paper's qualitative claim: KNN weak-scales
    with high efficiency when fragments >= workers."""
    costs = knn.calibrate(d=8, k=3, units=(200, 400))
    for workers in (4, 16):
        tasks = knn.dag_spec(costs, 2000, 1000 * workers, 8, 3,
                             train_fragments=workers, test_blocks=workers)
        r = simulate(tasks, MachineModel(n_nodes=1, workers_per_node=workers))
        base = knn.dag_spec(costs, 2000, 1000, 8, 3, train_fragments=workers,
                            test_blocks=1)
        r1 = simulate(base, MachineModel(n_nodes=1, workers_per_node=1))
        eff = r1.makespan * 1.0 / r.makespan  # weak: T(1 unit,1w)/T(N units,Nw)
        assert eff > 0.5, (workers, eff)
